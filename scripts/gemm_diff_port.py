#!/usr/bin/env python3
"""Differential reference port of the Rust xnor-GEMM kernel family.

This script is the cross-language leg of the kernel-correctness harness
(`rust/tests/gemm_differential.rs`): it re-implements the bit-packing
convention and every popcount kernel *algorithm* from
`rust/src/gemm/{pack,simd,fused}.rs` in Python and checks them
bit-exactly against a naive ±1 float GEMM.  The AVX2 Harley–Seal kernel
is simulated exactly: each 256-bit vector register is a masked Python
int, and because every instruction the kernel uses (xor/and/or, and a
final per-lane popcount whose lanes are ultimately summed) is
lane-independent, the simulation reproduces the real kernel's arithmetic
including the CSA tier ordering, the 64-word block loop, the 4-word
remainder loop and the scalar tail — the places tail bugs live.

It also ports the integer-threshold epilogue (`rust/src/gemm/fused.rs` +
`rust/src/nn/layers.rs::fold_sign_rules`): BN scale/shift is computed
with per-op float32 rounding exactly like the Rust f32 code, folded into
per-channel popcount threshold rules (Ge/Le/Const, negative gamma flips
the compare, zero variance saturates), and the fused compare epilogue is
checked bit-exactly against the unfused f32 BN+sign reference — plus the
2×2 register-tile microkernel and the bit-domain OR-maxpool identity.

Modes:
  default         run the differential suite; exit nonzero on any mismatch
  --bench PATH    additionally time the port's implementations on the
                  reduced Figure 1-3 shapes and write PATH as a schema-2
                  perf record (see rust/src/bench/record.rs): cell ids
                  `fig1/C=64/naive` etc. with median/min/MAD over reps,
                  plus a provenance block

The --bench timings come from *this Python port*, not the Rust kernels;
the emitted provenance block says so (`rustc: "unavailable (python
port)"`).  They seed the record so EXPERIMENTS.md has real measured
numbers — and a comparable baseline for `bmxnet bench-compare` — until a
Rust toolchain is available to regenerate via
`bmxnet bench-gemm --json BENCH_gemm.json` or
`bmxnet bench-suite --json out/`.
"""

import argparse
import json
import math
import os
import platform
import statistics
import subprocess
import sys
import time

import numpy as np

WORD_BITS = 64
M64 = (1 << 64) - 1
M256 = (1 << 256) - 1

# ---------------------------------------------------------------------------
# Packing (rust/src/gemm/pack.rs)
# ---------------------------------------------------------------------------


def pack_row(row, side):
    """LSB-first sign packing of one logical row; side 'A' pads 1s, 'B' 0s."""
    words = []
    for base in range(0, len(row), WORD_BITS):
        chunk = row[base : base + WORD_BITS]
        w = 0
        for b, v in enumerate(chunk):
            if v >= 0.0:
                w |= 1 << b
        if len(chunk) < WORD_BITS and side == "A":
            w |= (M64 << len(chunk)) & M64
        words.append(w)
    return words


def pack_rows(data, rows, k, side):
    return [pack_row(data[r * k : (r + 1) * k], side) for r in range(rows)]


def pack_cols(data, k, n):
    """B-operand layout: packed row j holds column j of the (k, n) matrix."""
    return [pack_row([data[kk * n + j] for kk in range(k)], "B") for j in range(n)]


# ---------------------------------------------------------------------------
# Row kernels (rust/src/gemm/simd.rs)
# ---------------------------------------------------------------------------


def scalar_row(arow, brow):
    return sum((~(a ^ b) & M64).bit_count() for a, b in zip(arow, brow))


def _vec4(words, i):
    """Simulate _mm256_loadu_si256 of words[i..i+4] (little-endian lanes)."""
    return words[i] | words[i + 1] << 64 | words[i + 2] << 128 | words[i + 3] << 192


def _xnor4(arow, brow, i):
    return ~(_vec4(arow, i) ^ _vec4(brow, i)) & M256


def _csa(a, b, c):
    u = a ^ b
    return (a & b) | (u & c), u ^ c


def avx2_row(arow, brow):
    """Exact simulation of x86::row_avx2 (Harley-Seal CSA over 16 vectors).

    The per-lane popcount accumulators are modelled as one integer (their
    lane sum): every CSA tier count is < 2^60, so per-lane u64 counters
    never overflow and summing lanes early is arithmetically identical to
    the kernel's final lane reduction.
    """
    n = min(len(arow), len(brow))
    total = ones = twos = fours = eights = 0
    i = 0
    while i + 64 <= n:
        twos_a, ones = _csa(ones, _xnor4(arow, brow, i), _xnor4(arow, brow, i + 4))
        twos_b, ones = _csa(ones, _xnor4(arow, brow, i + 8), _xnor4(arow, brow, i + 12))
        fours_a, twos = _csa(twos, twos_a, twos_b)
        twos_a, ones = _csa(ones, _xnor4(arow, brow, i + 16), _xnor4(arow, brow, i + 20))
        twos_b, ones = _csa(ones, _xnor4(arow, brow, i + 24), _xnor4(arow, brow, i + 28))
        fours_b, twos = _csa(twos, twos_a, twos_b)
        eights_a, fours = _csa(fours, fours_a, fours_b)
        twos_a, ones = _csa(ones, _xnor4(arow, brow, i + 32), _xnor4(arow, brow, i + 36))
        twos_b, ones = _csa(ones, _xnor4(arow, brow, i + 40), _xnor4(arow, brow, i + 44))
        fours_a, twos = _csa(twos, twos_a, twos_b)
        twos_a, ones = _csa(ones, _xnor4(arow, brow, i + 48), _xnor4(arow, brow, i + 52))
        twos_b, ones = _csa(ones, _xnor4(arow, brow, i + 56), _xnor4(arow, brow, i + 60))
        fours_b, twos = _csa(twos, twos_a, twos_b)
        eights_b, fours = _csa(fours, fours_a, fours_b)
        sixteens, eights = _csa(eights, eights_a, eights_b)
        total += sixteens.bit_count()
        i += 64
    total = (total << 4) + (eights.bit_count() << 3) + (fours.bit_count() << 2)
    total += (twos.bit_count() << 1) + ones.bit_count()
    while i + 4 <= n:
        total += _xnor4(arow, brow, i).bit_count()
        i += 4
    while i < n:
        total += (~(arow[i] ^ brow[i]) & M64).bit_count()
        i += 1
    return total


def avx512_row(arow, brow):
    """Simulation of x86_512::row_avx512: 8 words/step, scalar tail."""
    n = min(len(arow), len(brow))
    total = 0
    i = 0
    while i + 8 <= n:
        total += sum((~(arow[i + j] ^ brow[i + j]) & M64).bit_count() for j in range(8))
        i += 8
    while i < n:
        total += (~(arow[i] ^ brow[i]) & M64).bit_count()
        i += 1
    return total


def neon_row(arow, brow):
    """Simulation of arm::row_neon: 2 words/step, scalar tail."""
    n = min(len(arow), len(brow))
    total = 0
    i = 0
    while i + 2 <= n:
        total += (~(arow[i] ^ brow[i]) & M64).bit_count()
        total += (~(arow[i + 1] ^ brow[i + 1]) & M64).bit_count()
        i += 2
    while i < n:
        total += (~(arow[i] ^ brow[i]) & M64).bit_count()
        i += 1
    return total


def u32_row(arow, brow):
    """The xnor_32 reduction: same words viewed as u32 halves."""
    total = 0
    for a, b in zip(arow, brow):
        for half in (0, 32):
            aa, bb = (a >> half) & 0xFFFFFFFF, (b >> half) & 0xFFFFFFFF
            total += (~(aa ^ bb) & 0xFFFFFFFF).bit_count()
    return total


KERNELS = {
    "scalar": scalar_row,
    "avx2": avx2_row,
    "avx512": avx512_row,
    "neon": neon_row,
    "xnor_32": u32_row,
}


def tile2x2_avx2(a0, a1, b0, b1):
    """Exact simulation of x86::tile2x2_avx2 (2×2 register tile).

    Each 256-bit accumulator holds per-64-bit-lane popcount sums; as in
    `avx2_row`, modelling the four accumulators by their lane sums is
    arithmetically identical to the kernel's final `lane_sum` reduction.
    """
    n = min(len(a0), len(a1), len(b0), len(b1))
    c = [0, 0, 0, 0]
    i = 0
    while i + 4 <= n:
        va0, va1 = _vec4(a0, i), _vec4(a1, i)
        vb0, vb1 = _vec4(b0, i), _vec4(b1, i)
        c[0] += (~(va0 ^ vb0) & M256).bit_count()
        c[1] += (~(va0 ^ vb1) & M256).bit_count()
        c[2] += (~(va1 ^ vb0) & M256).bit_count()
        c[3] += (~(va1 ^ vb1) & M256).bit_count()
        i += 4
    while i < n:
        c[0] += (~(a0[i] ^ b0[i]) & M64).bit_count()
        c[1] += (~(a0[i] ^ b1[i]) & M64).bit_count()
        c[2] += (~(a1[i] ^ b0[i]) & M64).bit_count()
        c[3] += (~(a1[i] ^ b1[i]) & M64).bit_count()
        i += 1
    return c

# ---------------------------------------------------------------------------
# GEMM entry points (dispatch.rs / fused.rs)
# ---------------------------------------------------------------------------


def xnor_gemm(pa, pb, row_fn):
    return [[row_fn(ar, br) for br in pb] for ar in pa]


def fused_gemm(a, m, k, pb, row_fn, tile_fn=None, mr=8, jb=64):
    """rust/src/gemm/fused.rs: MR-row panel packing, JB-column B tiles,
    2×2 register-tile main loop with single-row cleanup on odd edges."""
    n = len(pb)
    if tile_fn is None:
        tile_fn = lambda a0, a1, b0, b1: [
            row_fn(a0, b0), row_fn(a0, b1), row_fn(a1, b0), row_fn(a1, b1)
        ]
    c = [[0] * n for _ in range(m)]
    for ic in range(0, m, mr):
        mb = min(mr, m - ic)
        panel = [pack_row(a[(ic + di) * k : (ic + di + 1) * k], "A") for di in range(mb)]
        for jc in range(0, n, jb):
            nb = min(jb, n - jc)
            di = 0
            while di + 2 <= mb:
                dj = 0
                while dj + 2 <= nb:
                    t = tile_fn(panel[di], panel[di + 1], pb[jc + dj], pb[jc + dj + 1])
                    c[ic + di][jc + dj] = t[0]
                    c[ic + di][jc + dj + 1] = t[1]
                    c[ic + di + 1][jc + dj] = t[2]
                    c[ic + di + 1][jc + dj + 1] = t[3]
                    dj += 2
                while dj < nb:  # odd column tail
                    c[ic + di][jc + dj] = row_fn(panel[di], pb[jc + dj])
                    c[ic + di + 1][jc + dj] = row_fn(panel[di + 1], pb[jc + dj])
                    dj += 1
                di += 2
            while di < mb:  # odd row tail
                for dj in range(nb):
                    c[ic + di][jc + dj] = row_fn(panel[di], pb[jc + dj])
                di += 1
    return c


# ---------------------------------------------------------------------------
# BN+sign threshold folding (rust/src/gemm/fused.rs fold_bn_sign and
# rust/src/nn/layers.rs BatchNorm::scale_shift) — strict f32 per-op port
# ---------------------------------------------------------------------------

BN_EPS = np.float32(1e-5)


def bn_scale_shift(gamma, beta, mean, var):
    """BatchNorm::scale_shift with each op rounded to f32, like Rust."""
    g, be = np.float32(gamma), np.float32(beta)
    mu, v = np.float32(mean), np.float32(var)
    scale = np.float32(g / np.sqrt(np.float32(v + BN_EPS)))
    shift = np.float32(be - np.float32(mu * scale))
    return scale, shift


def fold_bn_sign(scale, shift, k):
    """Port of fused::fold_bn_sign: candidate threshold from exact f64
    algebra, then locally walked against the exact f32 reference so the
    rule reproduces `scale * dot + shift >= 0` for every popcount."""
    scale, shift = np.float32(scale), np.float32(shift)

    def fires(p):
        return bool(scale * np.float32(2 * p - k) + shift >= np.float32(0.0))

    if scale == np.float32(0.0):
        return ("const", bool(shift >= np.float32(0.0)))
    cand = (-float(shift) / float(scale) + k) / 2.0
    if scale > 0.0:
        t = min(max(math.ceil(cand), 0), k + 1)
        while t > 0 and fires(t - 1):
            t -= 1
        while t <= k and not fires(t):
            t += 1
        return ("ge", t)
    t = min(max(math.floor(cand), -1), k)
    while t < k and fires(t + 1):
        t += 1
    while t >= 0 and not fires(t):
        t -= 1
    return ("le", t)


def rule_fires(rule, p):
    op, v = rule
    if op == "ge":
        return p >= v
    if op == "le":
        return p <= v
    return v


def fused_gemm_threshold(a, m, k, pb, rules, row_fn, tile_fn=None, mr=8, jb=64):
    """fused::gemm_fused_threshold: popcounts compared per channel against
    the folded rules, sign bits written to A-side-padded packed rows."""
    pops = fused_gemm(a, m, k, pb, row_fn, tile_fn, mr, jb)
    n = len(pb)
    wpr = (n + 63) // 64
    out = []
    for i in range(m):
        words = [0] * wpr
        if n % 64:
            words[-1] = (M64 << (n % 64)) & M64  # next layer's A-side pads
        for j in range(n):
            if rule_fires(rules[j], pops[i][j]):
                words[j // 64] |= 1 << (j % 64)
        out.append(words)
    return out


def naive_reference(a, b, m, n, k):
    """Sign-binarize then float GEMM; returns the ±1 dot matrix."""
    sa = np.where(np.asarray(a, dtype=np.float64).reshape(m, k) >= 0.0, 1.0, -1.0)
    sb = np.where(np.asarray(b, dtype=np.float64).reshape(k, n) >= 0.0, 1.0, -1.0)
    return sa @ sb


# ---------------------------------------------------------------------------
# Differential suite
# ---------------------------------------------------------------------------

EDGE_SHAPES = [
    (1, 1, 1), (1, 1, 63), (1, 1, 64), (1, 1, 65), (1, 5, 127), (5, 1, 128),
    (3, 3, 129), (2, 2, 191), (3, 3, 192), (7, 3, 1000), (1, 64, 256),
    (9, 65, 64), (4, 4, 4096), (4, 4, 4097),
]


def run_differential(verbose=True):
    rng = np.random.default_rng(20260807)
    failures = 0
    shapes = list(EDGE_SHAPES)
    for _ in range(24):
        shapes.append(
            (int(rng.integers(1, 12)), int(rng.integers(1, 80)), int(rng.integers(1, 600)))
        )
    for m, n, k in shapes:
        a = rng.standard_normal(m * k).tolist()
        b = rng.standard_normal(k * n).tolist()
        expect = naive_reference(a, b, m, n, k)
        pa = pack_rows(a, m, k, "A")
        pb = pack_cols(b, k, n)
        for name, row_fn in KERNELS.items():
            pops = xnor_gemm(pa, pb, row_fn)
            dots = np.array([[2 * p - k for p in prow] for prow in pops], dtype=np.float64)
            if not np.array_equal(dots, expect):
                print(f"FAIL kernel={name} m={m} n={n} k={k}")
                failures += 1
        fused = fused_gemm(a, m, k, pb, avx2_row)
        fdots = np.array([[2 * p - k for p in row] for row in fused], dtype=np.float64)
        if not np.array_equal(fdots, expect):
            print(f"FAIL fused m={m} n={n} k={k}")
            failures += 1
    # constants: all-match -> pop=k, all-mismatch -> pop=0, zeros -> +1
    for k in (1, 63, 64, 65, 129, 1000):
        plus, minus, zeros = [1.0] * k, [-1.0] * k, [0.0] * k
        pb_plus = pack_cols(plus, k, 1)
        for name, row_fn in KERNELS.items():
            if xnor_gemm(pack_rows(plus, 1, k, "A"), pb_plus, row_fn)[0][0] != k:
                print(f"FAIL {name} all-match k={k}")
                failures += 1
            if xnor_gemm(pack_rows(minus, 1, k, "A"), pb_plus, row_fn)[0][0] != 0:
                print(f"FAIL {name} all-mismatch k={k}")
                failures += 1
            if xnor_gemm(pack_rows(zeros, 1, k, "A"), pb_plus, row_fn)[0][0] != k:
                print(f"FAIL {name} zeros-as-plus k={k}")
                failures += 1
    # pad convention: A pads 1s, B pads 0s; one flipped B pad bit adds 1
    for k in (10, 63, 100):
        pad_mask = (M64 << (k % 64)) & M64
        vals = [(-1.0) ** i for i in range(k)]
        assert pack_rows(vals, 1, k, "A")[0][-1] & pad_mask == pad_mask
        assert pack_rows(vals, 1, k, "B")[0][-1] & pad_mask == 0
        pa1 = pack_rows(vals, 1, k, "A")
        pb1 = pack_cols(vals, k, 1)
        clean = scalar_row(pa1[0], pb1[0])
        corrupt = list(pb1[0])
        corrupt[-1] |= 1 << (k % 64)
        if scalar_row(pa1[0], corrupt) != clean + 1:
            print(f"FAIL pad-corruption k={k}")
            failures += 1
    if verbose:
        n_checks = len(shapes) * (len(KERNELS) + 1)
        print(f"differential suite: {n_checks} GEMM comparisons, {failures} failures")
    return failures


def run_fold_differential(verbose=True):
    """Threshold-fold leg: fold math exhaustive over popcounts, the fused
    threshold epilogue vs the unfused f32 BN+sign reference (negative
    gamma, zero variance, dead channels, odd channel counts), the 2×2 tile
    vs four row reductions, and the bit-domain OR-pool identity."""
    failures = 0
    # 1) fold math: every rule must reproduce the f32 decision at every
    #    reachable popcount, including saturating shifts
    k = 65
    scales = [0.0, 1.0, -1.0, 0.004, -0.004, 300.0, -300.0, 1e-30, -1e-30]
    shifts = [0.0, 0.5, -0.5, 1e-3, -1e-3, 64.9, -64.9, 1e9, -1e9]
    for s in scales:
        for sh in shifts:
            rule = fold_bn_sign(s, sh, k)
            for p in range(k + 1):
                ref = bool(
                    np.float32(s) * np.float32(2 * p - k) + np.float32(sh) >= np.float32(0.0)
                )
                if rule_fires(rule, p) != ref:
                    print(f"FAIL fold scale={s} shift={sh} p={p} rule={rule}")
                    failures += 1
    # 2) raw BN params -> rules -> fused threshold epilogue, bit-exact vs
    #    the unfused reference on the same popcounts
    rng = np.random.default_rng(97)
    for m, n, k in [(4, 7, 33), (3, 65, 64), (9, 100, 800), (2, 64, 129)]:
        a = rng.standard_normal(m * k).tolist()
        b = rng.standard_normal(k * n).tolist()
        gamma = rng.standard_normal(n).astype(np.float32)
        gamma[::3] *= np.float32(-1.0)  # negative gamma flips the compare
        if n > 2:
            gamma[2] = 0.0  # dead channel -> Const rule
        beta = rng.standard_normal(n).astype(np.float32)
        mean = rng.standard_normal(n).astype(np.float32)
        var = np.abs(rng.standard_normal(n)).astype(np.float32)
        var[0] = 0.0  # zero-variance channel
        sc_sh = [bn_scale_shift(gamma[j], beta[j], mean[j], var[j]) for j in range(n)]
        rules = [fold_bn_sign(sc, sh, k) for sc, sh in sc_sh]
        pb = pack_cols(b, k, n)
        pops = fused_gemm(a, m, k, pb, avx2_row, tile2x2_avx2)
        bits = fused_gemm_threshold(a, m, k, pb, rules, avx2_row, tile2x2_avx2)
        for i in range(m):
            for j in range(n):
                sc, sh = sc_sh[j]
                ref = bool(sc * np.float32(2 * pops[i][j] - k) + sh >= np.float32(0.0))
                got = bool(bits[i][j // 64] >> (j % 64) & 1)
                if got != ref:
                    print(f"FAIL thr-epilogue m={m} n={n} k={k} ({i},{j})")
                    failures += 1
        if n % 64:
            pad = (M64 << (n % 64)) & M64
            for i in range(m):
                if bits[i][-1] & pad != pad:
                    print(f"FAIL thr pad bits m={m} n={n} k={k} row={i}")
                    failures += 1
    # 3) the 2×2 tile is a pure reordering of four row reductions
    rng2 = np.random.default_rng(5)
    for words in (0, 1, 3, 4, 5, 8, 65):
        a0, a1, b0, b1 = (
            [int(x) for x in rng2.integers(0, 1 << 64, words, dtype=np.uint64)]
            for _ in range(4)
        )
        expect = [scalar_row(a0, b0), scalar_row(a0, b1), scalar_row(a1, b0), scalar_row(a1, b1)]
        if tile2x2_avx2(a0, a1, b0, b1) != expect:
            print(f"FAIL tile2 words={words}")
            failures += 1
    # 4) bit-domain maxpool == OR: sign(max(y)) == OR(sign(y)) always
    y = rng.standard_normal((256, 4)).astype(np.float32)
    if not np.array_equal((y >= 0).any(axis=1), y.max(axis=1) >= 0):
        print("FAIL or-pool identity")
        failures += 1
    if verbose:
        print(
            f"threshold-fold suite: {len(scales) * len(shifts)} fold cells, "
            f"4 epilogue shapes, {failures} failures"
        )
    return failures


# ---------------------------------------------------------------------------
# Bench mode: seed BENCH_gemm.json (numpy-vectorized port timings) as a
# schema-2 perf record matching rust/src/bench/record.rs bit-for-concept
# (same cell ids, same stats, same provenance keys) so bench-compare can
# align a future Rust-generated record against this seed.
# ---------------------------------------------------------------------------


def np_pack_bits(signs_2d, pad_value):
    """Pack a (rows, k) boolean sign matrix into (rows, wpr) uint64 words."""
    rows, k = signs_2d.shape
    wpr = (k + 63) // 64
    padded = np.full((rows, wpr * 64), pad_value, dtype=bool)
    padded[:, :k] = signs_2d
    bits = np.packbits(padded, axis=1, bitorder="little")
    return bits.view(np.uint64)


def np_xnor_gemm(pa, pb):
    """Vectorized popcount GEMM on packed uint64 operands."""
    # (m, 1, wpr) ^ (1, n, wpr) -> bitwise_count sum over words
    x = ~(pa[:, None, :] ^ pb[None, :, :])
    return np.bitwise_count(x).sum(axis=2, dtype=np.int64)


def cpu_flags():
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.startswith("flags"):
                    return set(line.split(":", 1)[1].split())
    except OSError:
        pass
    return set()


def bench_methods():
    """The Method labels dispatchable on this machine, in catalog order."""
    flags = cpu_flags()
    methods = ["naive", "cblas", "xnor_32", "xnor_64", "xnor_64_blk", "xnor_64_omp"]
    if "avx2" in flags:
        methods.append("xnor_64_avx2")
    # xnor_64_avx512 needs the off-by-default simd-avx512 cargo feature
    # AND avx512vpopcntdq; mirror the Rust default-feature dispatch.
    methods.append("xnor_fused")
    return methods


def time_stats(reps, fn):
    """median/min/MAD over reps in ms, after one untimed warmup —
    mirrors rust/src/bench/harness.rs `time_stats`."""
    fn()
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        samples.append((time.perf_counter() - t0) * 1e3)
    med = statistics.median(samples)
    mad = statistics.median([abs(s - med) for s in samples])
    return {"median": med, "min": min(samples), "mad": mad, "reps": reps}


def figure_workloads():
    """Reduced Figure 1-3 shapes (rust/src/bench/workloads.rs, batch 20)."""
    batch = 20
    fig1 = [("fig1", "C", True, c, 64, batch * 64, 25 * c) for c in (64, 128, 256, 512)]
    fig2 = [("fig2", "filters", False, f, f, batch * 64, 6400) for f in (16, 32, 64, 128, 256, 512)]
    fig3 = [("fig3", "kernel", False, ks, 64, batch * 64, ks * ks * 256) for ks in range(1, 9)]
    return fig1 + fig2 + fig3


def crate_version():
    cargo = os.path.join(os.path.dirname(__file__), "..", "rust", "Cargo.toml")
    try:
        with open(cargo) as f:
            for line in f:
                if line.startswith("version"):
                    return line.split('"')[1]
    except OSError:
        pass
    return "unknown"


def git_describe():
    try:
        out = subprocess.run(
            ["git", "describe", "--always", "--dirty", "--tags"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        )
        if out.returncode == 0:
            return out.stdout.strip()
    except OSError:
        pass
    return "unknown"


def port_provenance(reps):
    """The same 14 keys Provenance::capture emits, honestly stamped as a
    Python-port measurement (rustc/dispatch/kernels say so)."""
    return {
        "tool": "scripts/gemm_diff_port.py --bench",
        "version": crate_version(),
        "git": git_describe(),
        "rustc": "unavailable (python port)",
        "features": "python-port",
        "arch": platform.machine() or "unknown",
        "os": sys.platform,
        "cores": os.cpu_count() or 1,
        "dispatch": "python-port (numpy bitwise_count)",
        "force_scalar": False,
        "kernels": "numpy",
        "reps": reps,
        "quick": False,
        "note": (
            "python reference-port measurement (no Rust toolchain in the "
            "build container) - reduced shapes (batch 20) - method columns "
            "are behaviorally equivalent ports, so per-method deltas are "
            "NOT representative of the Rust kernels; regenerate with "
            "`bmxnet bench-suite --json out/` or "
            "`bmxnet bench-gemm --json BENCH_gemm.json`"
        ),
    }


def run_bench(out_path, reps):
    rng = np.random.default_rng(42)
    methods = bench_methods()
    cells = []
    for fig, xlabel, _absolute, x, m, n, k in figure_workloads():
        a = rng.standard_normal((m, k)).astype(np.float32)
        b = rng.standard_normal((k, n)).astype(np.float32)
        sa, sb = np.where(a >= 0, 1.0, -1.0), np.where(b >= 0, 1.0, -1.0)
        pa = np_pack_bits(a >= 0, True)   # A-side pads 1
        pb = np_pack_bits((b >= 0).T, False)  # B columns, pads 0
        pa32, pb32 = pa.view(np.uint32), pb.view(np.uint32)
        stats = {}
        for label in methods:
            if label == "naive":
                stats[label] = time_stats(reps, lambda: sa.astype(np.float64) @ sb)
            elif label == "cblas":
                stats[label] = time_stats(reps, lambda: sa @ sb)
            elif label == "xnor_32":
                stats[label] = time_stats(
                    reps,
                    lambda: np.bitwise_count(
                        ~(pa32[:, None, :] ^ pb32[None, :, :])
                    ).sum(axis=2, dtype=np.int64),
                )
            elif label == "xnor_fused":
                stats[label] = time_stats(
                    reps, lambda: np_xnor_gemm(np_pack_bits(a >= 0, True), pb)
                )
            else:  # xnor_64 / _blk / _omp / _avx2: one packed-word GEMM here
                stats[label] = time_stats(reps, lambda: np_xnor_gemm(pa, pb))
        stats["bin+xnor_omp"] = time_stats(
            reps, lambda: np_xnor_gemm(np_pack_bits(a >= 0, True), pb)
        )
        for label, s in stats.items():
            cells.append({"id": f"{fig}/{xlabel}={x}/{label}", "unit": "ms", **s})
        print(
            f"{fig} {xlabel}={x}: "
            + " ".join(f"{l}={s['median']:.1f}ms" for l, s in stats.items())
        )
    doc = {
        "schema": 2,
        "bench": "gemm",
        "provenance": port_provenance(reps),
        "cells": cells,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path} ({len(cells)} cells, schema 2)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", metavar="PATH", help="also write BENCH_gemm.json to PATH")
    ap.add_argument("--reps", type=int, default=3, help="timed reps per cell for --bench")
    args = ap.parse_args()
    failures = run_differential() + run_fold_differential()
    if failures:
        sys.exit(1)
    if args.bench:
        run_bench(args.bench, args.reps)


if __name__ == "__main__":
    main()
