#!/usr/bin/env sh
# Serving-gateway smoke test: start `bmxnet serve` on an ephemeral port,
# list models, run one classify on each acceptance model, check /metrics.
# Run from the repo root (models resolve from ./artifacts via the
# manifest).  Used by `make serve-smoke` and CI.
set -eu

BIN=${BIN:-target/release/bmxnet}
MODELS_DIR=${MODELS_DIR:-artifacts}
LOG=$(mktemp /tmp/bmxnet_serve_smoke.XXXXXX)
SYNTH_DIR=""

if [ ! -x "$BIN" ]; then
    echo "serve-smoke: $BIN not built (run \`make build\` first)" >&2
    exit 1
fi

# artifacts/ is gitignored: on a fresh clone (CI included) fall back to
# synthetic-weight models so the smoke test runs anywhere.
if [ ! -f "$MODELS_DIR/manifest.json" ] && [ ! -f "$MODELS_DIR/lenet_bin.bmx" ]; then
    SYNTH_DIR=$(mktemp -d /tmp/bmxnet_smoke_models.XXXXXX)
    echo "serve-smoke: $MODELS_DIR has no models; synthesizing into $SYNTH_DIR"
    "$BIN" synth-models --out "$SYNTH_DIR"
    MODELS_DIR=$SYNTH_DIR
fi

"$BIN" serve --models-dir "$MODELS_DIR" --workers 2 --port 0 >"$LOG" 2>&1 &
PID=$!
cleanup() {
    kill "$PID" 2>/dev/null || true
    rm -f "$LOG" /tmp/bmxnet_smoke_body.$$ /tmp/bmxnet_smoke_f32.$$ \
        /tmp/bmxnet_smoke_packed.$$ || true
    [ -n "$SYNTH_DIR" ] && rm -rf "$SYNTH_DIR" || true
}
trap cleanup EXIT INT TERM

# wait for the gateway to print its bound address
ADDR=""
i=0
while [ $i -lt 100 ]; do
    ADDR=$(sed -n 's#^listening on http://##p' "$LOG" | head -n 1)
    [ -n "$ADDR" ] && break
    if ! kill -0 "$PID" 2>/dev/null; then
        echo "serve-smoke: gateway died during startup:" >&2
        cat "$LOG" >&2
        exit 1
    fi
    i=$((i + 1))
    sleep 0.1
done
if [ -z "$ADDR" ]; then
    echo "serve-smoke: gateway never reported its address:" >&2
    cat "$LOG" >&2
    exit 1
fi
echo "serve-smoke: gateway at $ADDR"

# 784 zeros is a valid (if boring) 28x28 LeNet input
BODY=/tmp/bmxnet_smoke_body.$$
awk 'BEGIN{printf "{\"image\":["; for(i=0;i<783;i++) printf "0,"; print "0]}"}' >"$BODY"

curl -fsS "http://$ADDR/v1/models" | grep -q '"lenet_bin"' \
    || { echo "serve-smoke: lenet_bin missing from /v1/models" >&2; exit 1; }

for MODEL in lenet_bin lenet_q4; do
    OUT=$(curl -fsS -X POST -H 'content-type: application/json' \
        --data-binary @"$BODY" "http://$ADDR/v1/models/$MODEL:classify")
    echo "serve-smoke: $MODEL -> $OUT"
    echo "$OUT" | grep -q '"class"' \
        || { echo "serve-smoke: $MODEL classify has no class field" >&2; exit 1; }
done

# binary request bodies (PR 10): raw LE f32 pixels and pre-packed sign
# bits must classify like their JSON equivalents.  784 zero pixels =
# 3136 zero f32 bytes; packed, 784 sign bits = 98 bytes (zeros pack to
# -1.0 everywhere, a different — but valid — all-negative input).
RAWF32=/tmp/bmxnet_smoke_f32.$$
PACKED=/tmp/bmxnet_smoke_packed.$$
head -c 3136 /dev/zero >"$RAWF32"
head -c 98 /dev/zero >"$PACKED"
OUT=$(curl -fsS -X POST -H 'content-type: application/x-bmx-f32' \
    --data-binary @"$RAWF32" "http://$ADDR/v1/models/lenet_bin:classify")
echo "serve-smoke: lenet_bin (x-bmx-f32) -> $OUT"
echo "$OUT" | grep -q '"class"' \
    || { echo "serve-smoke: x-bmx-f32 classify has no class field" >&2; exit 1; }
OUT=$(curl -fsS -X POST -H 'content-type: application/x-bmx-packed' \
    --data-binary @"$PACKED" "http://$ADDR/v1/models/lenet_bin:classify")
echo "serve-smoke: lenet_bin (x-bmx-packed) -> $OUT"
echo "$OUT" | grep -q '"class"' \
    || { echo "serve-smoke: x-bmx-packed classify has no class field" >&2; exit 1; }
rm -f "$RAWF32" "$PACKED"

# counters are recorded just after the reply is written; give them a beat
sleep 0.5
METRICS=$(curl -fsS "http://$ADDR/metrics")
echo "$METRICS" | grep -q 'bmxnet_requests_total{model="lenet_bin"} 1' \
    || { echo "serve-smoke: /metrics missing lenet_bin request count" >&2; exit 1; }

# observability families (PR 7): per-stage histograms, kernel counters,
# per-shard queue depth, monotone latency count/sum; plus the build
# identity gauge (PR 8)
for FAMILY in \
    'bmxnet_stage_latency_us_bucket{stage="parse"' \
    'bmxnet_stage_latency_us_bucket{stage="forward"' \
    'bmxnet_kernel_calls_total{method=' \
    'bmxnet_queue_depth{model="lenet_bin",shard="0"}' \
    'bmxnet_latency_us_count{model="lenet_bin"}' \
    'bmxnet_latency_us_sum{model="lenet_bin"}' \
    'bmxnet_build_info{version="' \
    'bmxnet_trace_total' \
    'bmxnet_active_connections' \
    'bmxnet_conns_shed_total' \
    'bmxnet_reactor_loop_us_bucket{worker="0"' \
    'bmxnet_stage_latency_us_bucket{stage="read"' \
    'bmxnet_stage_latency_us_bucket{stage="write"'; do
    echo "$METRICS" | grep -qF "$FAMILY" \
        || { echo "serve-smoke: /metrics missing $FAMILY" >&2; exit 1; }
done

# the debug trace journal has the classify requests, with named stages
TRACES=$(curl -fsS "http://$ADDR/v1/debug/trace?n=4")
echo "serve-smoke: traces -> $TRACES"
for KEY in '"traces"' '"stages_us"' '"forward"' '"respond"'; do
    echo "$TRACES" | grep -qF "$KEY" \
        || { echo "serve-smoke: /v1/debug/trace missing $KEY" >&2; exit 1; }
done

# per-model dispatch + build identity surface in the listing
LISTING=$(curl -fsS "http://$ADDR/v1/models")
echo "$LISTING" | grep -q '"force_scalar"' \
    || { echo "serve-smoke: /v1/models missing force_scalar" >&2; exit 1; }
echo "$LISTING" | grep -q '"build_info"' \
    || { echo "serve-smoke: /v1/models missing build_info" >&2; exit 1; }

echo "serve-smoke: OK"
