#!/usr/bin/env sh
# Perf-radar smoke test: run the unified bench suite in quick mode, check
# that every family emits a schema-2 record with populated provenance,
# prove the compare gate passes on a self-compare, and prove it FAILS
# (non-zero exit) on an injected 50% regression.  Used by
# `make perf-smoke` and CI.
#
# BMXNET_FORCE_SCALAR=1 pins the scalar kernel so the run is portable;
# timing noise is irrelevant because the self-compare is literally the
# same files and the injected regression zeroes the MAD noise floor.
set -eu

BIN=${BIN:-target/release/bmxnet}
PYTHON=${PYTHON:-python3}

if [ ! -x "$BIN" ]; then
    echo "perf-smoke: $BIN not built (run \`make build\` first)" >&2
    exit 1
fi

DIR=$(mktemp -d /tmp/bmxnet_perf_smoke.XXXXXX)
cleanup() { rm -rf "$DIR" || true; }
trap cleanup EXIT INT TERM

# --- 1. quick suite run: one record per family, schema + provenance
BMXNET_FORCE_SCALAR=1 "$BIN" bench-suite --quick --json "$DIR/base"

for FAM in gemm tables engine serve serve_policy serve_conns profile; do
    REC="$DIR/base/BENCH_$FAM.json"
    [ -f "$REC" ] || { echo "perf-smoke: missing $REC" >&2; exit 1; }
    for NEEDLE in '"schema": 2' "\"bench\": \"$FAM\"" '"git":' '"rustc":' \
        '"dispatch":' '"cells":'; do
        grep -qF "$NEEDLE" "$REC" \
            || { echo "perf-smoke: $REC missing $NEEDLE" >&2; exit 1; }
    done
done

# Engine cells must carry the epilogue label: the folded lenet_bin emits
# forward/thr cells, the k-bit lenet_q4 stays on the float BN (f32bn).
for NEEDLE in 'forward/thr' 'forward/f32bn'; do
    grep -qF "$NEEDLE" "$DIR/base/BENCH_engine.json" \
        || { echo "perf-smoke: engine record missing $NEEDLE cells" >&2; exit 1; }
done

# --- 2. self-compare must pass (dir vs dir, exit 0)
"$BIN" bench-compare "$DIR/base" "$DIR/base" \
    || { echo "perf-smoke: self-compare failed" >&2; exit 1; }

# --- 2b. BMXNET_NO_FOLD=1 leg: the float-epilogue path must also bench
# and self-compare cleanly, and its cell ids must not claim the folded
# label (disjoint ids mean bench-compare never mixes the two epilogues).
BMXNET_FORCE_SCALAR=1 BMXNET_NO_FOLD=1 \
    "$BIN" bench-suite --quick --filter engine --json "$DIR/nofold"
grep -qF 'forward/f32bn' "$DIR/nofold/BENCH_engine.json" \
    || { echo "perf-smoke: no-fold engine record missing f32bn cells" >&2; exit 1; }
if grep -qF 'forward/thr' "$DIR/nofold/BENCH_engine.json"; then
    echo "perf-smoke: BMXNET_NO_FOLD=1 still emitted folded thr cells" >&2
    exit 1
fi
"$BIN" bench-compare "$DIR/nofold" "$DIR/nofold" \
    || { echo "perf-smoke: no-fold self-compare failed" >&2; exit 1; }

# --- 3. injected regression must fail (exit non-zero)
# Copy the records, zero every MAD (deterministic noise floor), and
# multiply the gemm medians by 1.5 in the "regressed" copy only.
"$PYTHON" - "$DIR" <<'EOF'
import json, pathlib, shutil, sys

root = pathlib.Path(sys.argv[1])
clean, bad = root / "clean", root / "bad"
shutil.copytree(root / "base", clean)
shutil.copytree(root / "base", bad)

def rewrite(path, scale):
    rec = json.loads(path.read_text())
    for cell in rec["cells"]:
        cell["mad"] = 0.0
        cell["median"] *= scale
        cell["min"] *= scale
    path.write_text(json.dumps(rec, indent=2) + "\n")

for p in clean.glob("BENCH_*.json"):
    rewrite(p, 1.0)
for p in bad.glob("BENCH_*.json"):
    rewrite(p, 1.5 if p.name == "BENCH_gemm.json" else 1.0)
EOF

if "$BIN" bench-compare "$DIR/clean" "$DIR/bad" --fail-on 10; then
    echo "perf-smoke: injected 50% regression was NOT caught" >&2
    exit 1
fi
echo "perf-smoke: injected regression correctly rejected"

# --- 4. single-file compare path + JSON verdict
"$BIN" bench-compare "$DIR/clean/BENCH_tables.json" \
    "$DIR/bad/BENCH_tables.json" --json | grep -qF '"failed": false' \
    || { echo "perf-smoke: single-file JSON compare failed" >&2; exit 1; }

echo "perf-smoke: OK"
