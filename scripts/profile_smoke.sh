#!/usr/bin/env sh
# Profiler smoke test: run `bmxnet profile` against a synthetic packed
# LeNet, check the human table and the JSON report (per-layer rows with
# GEMM method/kernel labels).  Used by `make profile-smoke` and CI.
set -eu

BIN=${BIN:-target/release/bmxnet}

if [ ! -x "$BIN" ]; then
    echo "profile-smoke: $BIN not built (run \`make build\` first)" >&2
    exit 1
fi

DIR=$(mktemp -d /tmp/bmxnet_profile_smoke.XXXXXX)
cleanup() { rm -rf "$DIR" || true; }
trap cleanup EXIT INT TERM

"$BIN" synth-models --out "$DIR"

TABLE=$("$BIN" profile --bmx "$DIR/lenet_bin.bmx" --batch 4 --reps 2)
echo "$TABLE"
for NEEDLE in conv2 fc1 xnor_fused dispatch; do
    echo "$TABLE" | grep -q "$NEEDLE" \
        || { echo "profile-smoke: table missing $NEEDLE" >&2; exit 1; }
done

# The JSON report is a schema-2 perf record: per-layer cells with the
# GEMM method/kernel labels carried in the cell notes, plus provenance.
JSON_OUT=$DIR/profile.json
"$BIN" profile --model lenet_bin --models-dir "$DIR" --batch 4 --reps 2 \
    --json "$JSON_OUT" >/dev/null
for NEEDLE in '"schema": 2' '"bench": "profile"' '"model": "lenet_bin"' \
    '"id": "forward/total"' '"id": "layer/conv2"' 'method=xnor_fused' \
    'kernel=' '"git":' '"dispatch":'; do
    grep -qF "$NEEDLE" "$JSON_OUT" \
        || { echo "profile-smoke: JSON missing $NEEDLE" >&2; exit 1; }
done

# forced-scalar runs must label the scalar kernel
BMXNET_FORCE_SCALAR=1 "$BIN" profile --bmx "$DIR/lenet_bin.bmx" \
    --batch 2 --reps 1 --json | grep -qF 'kernel=scalar' \
    || { echo "profile-smoke: BMXNET_FORCE_SCALAR=1 did not pin scalar" >&2; exit 1; }

echo "profile-smoke: OK"
