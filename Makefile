# Developer/CI entry points.  `make verify` is the tier-1 gate plus docs
# and bench compilation — exactly what .github/workflows/ci.yml runs.

CARGO ?= cargo
PYTHON ?= python

.PHONY: build test doc bench-compile serve-smoke profile-smoke perf-smoke fmt-check verify artifacts clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Docs must build warning-free (broken intra-doc links fail CI).
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

# Compile (but do not run) all 8 bench targets.
bench-compile:
	$(CARGO) bench --no-run

# Start the serving gateway on an ephemeral port, curl /v1/models plus one
# classify per acceptance model, assert 200s and a /metrics request count.
serve-smoke: build
	sh scripts/serve_smoke.sh

# Run `bmxnet profile` (table + JSON + forced-scalar) on synthetic models.
profile-smoke: build
	sh scripts/profile_smoke.sh

# Quick bench-suite under forced-scalar dispatch: every family emits a
# schema-2 record, self-compare passes, an injected regression fails.
perf-smoke: build
	sh scripts/perf_smoke.sh

fmt-check:
	$(CARGO) fmt --check

verify: build test doc bench-compile serve-smoke profile-smoke perf-smoke

# Emit the AOT HLO-text artifacts + manifest (optional; needs JAX).
# The Rust side skips artifact-driven tests when this has not run.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts

clean:
	$(CARGO) clean
